"""Serving launcher: continuous-batching Serdab engine over trust-domain pods.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \\
      --mesh 2x2 --stages 2 --microbatches 2 --slots 4 --requests 6

Thin CLI over ``repro.serving.ServingEngine`` (DESIGN.md §Serving engine):
plans a ``PlacementSpec`` over the registered trust domains (``--topology``
picks the registry; ``--space segment`` is the default PlacementSpec search,
``--space prefix`` the legacy trusted-prefix tree), serves a synthetic
stream of heterogeneous requests with continuous batching, and optionally
injects a straggler stage (``--inject-straggler STAGE:FACTOR``) to
demonstrate telemetry-driven live re-planning with stage-layout cache
migration. ``--verify-swap`` runs the same request stream twice — with and
without the injected straggler — and asserts the decoded token streams are
identical across the live swap (requires ``--no-seal``: boundary sealing
quantizes whichever activation crosses the cut, so moving the cut moves the
quantization noise). ``--topology sandwich --require-non-prefix`` asserts
the planned spec is NOT expressible in the prefix space (multiple untrusted
segments); ``--temperature``/``--top-k`` switch greedy decoding to
per-request-reproducible sampling.

AOT warmup & chunked prefill (DESIGN.md §AOT warmup & chunked prefill):
``--warmup`` compiles every serving shape at engine construction and
freezes the compile ledger; ``--assert-no-recompile`` then fails the run
if steady-state serving performed ANY new XLA compilation (the zero-
compile-stall guarantee, checked against the runtime's own compile
counter). ``--prefill-chunk N`` streams long prompts in N-token chunks
interleaved with decode ticks (bounded batch-mate inter-token latency);
``--verify-chunked`` serves the same stream again with chunking disabled
and asserts token-identical output (use with ``--f32 --no-seal`` — the
chunked attention path is a different, equally-correct float reduction
order, so bf16 argmax ties may flip).

Two-tier KV swap (DESIGN.md §Two-tier KV & swap): under a tight
``--num-pages`` pool the demand policy preempts; ``--preempt-policy swap``
(the default) seals the victim's pages to a host-side swap tier and
restores them bit-exactly on resume instead of recomputing the prefix.
``--verify-preempt`` reruns the stream under the recompute oracle and
undisturbed (``--num-pages 0``) and asserts all three token streams are
identical (use with ``--f32`` — swap restore is bit-exact, so only float
argmax ties could otherwise differ between resume paths).

Chaos injection (DESIGN.md §Fault injection & recovery): ``--chaos``
arms the deterministic seeded fault plane (`serving/faults.py`) —
sealed-payload tampering, telemetry stage stalls, handoff drop/delay
under ``--disagg``, pool-exhaustion storms, and (``--chaos-death P``)
device death mid-decode. ``--verify-recovery`` reruns the same stream
fault-free and asserts the chaotic run's token streams are identical
AND every injected fault is attributable to a named
``stats()["recovery"]`` counter (use with ``--f32 --no-seal``); with
``--warmup --assert-no-recompile`` the whole recovery ladder is also
proven compile-free.
"""
from __future__ import annotations

import argparse
import copy

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced as reduce_cfg
from repro.core.privacy import LM_SIM_DELTA
from repro.enclave.domain import sandwich_manager, two_enclave_manager
from repro.launch.mesh import make_mesh
from repro.models.api import build_model
from repro.serving import (EngineConfig, FaultConfig, ServingEngine,
                           pipelined_backend_available)

TOPOLOGIES = {
    "two-enclave": lambda stages: two_enclave_manager(),
    # 1 trusted CC pod + (stages-1) full-rate untrusted pods: the optimal
    # placement pipelines multiple untrusted segments — non-prefix by
    # construction (the legacy space allows only one untrusted suffix)
    "sandwich": lambda stages: sandwich_manager(max(1, stages - 1)),
}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="2x1", help="pod x data")
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4,
                    help="KV-cache slots == decode batch")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=8,
                    help="max synthetic prompt length (uniform 2..N)")
    ap.add_argument("--max-new", type=int, default=8,
                    help="tokens to generate per request")
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="submit one request every K engine steps")
    ap.add_argument("--max-seq", type=int, default=0,
                    help="legacy timeline horizon (0 = auto-size)")
    ap.add_argument("--kv-layout", default="paged",
                    choices=["paged", "timeline"],
                    help="paged per-slot KV cache (unbounded lifetime) or "
                         "the legacy shared-position timeline")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged layout)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="shared page-pool size (0 = all slots at full "
                         "request capacity; smaller pools exercise "
                         "admission back-pressure)")
    ap.add_argument("--page-policy", default="demand",
                    choices=["demand", "reserve"],
                    help="demand: allocate pages as generation reaches "
                         "them, COW prefix sharing + preemption on "
                         "exhaustion; reserve: admit only on worst-case "
                         "reservation (PR 5 baseline)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable the COW prefix index (demand policy)")
    ap.add_argument("--preempt-policy", default="auto",
                    choices=["auto", "swap", "recompute"],
                    help="swap: seal victim pages to the host tier and "
                         "restore them on resume (O(pages)); recompute: "
                         "drop pages and re-prefill on resume (PR 6 "
                         "baseline, O(generated tokens)); auto (default): "
                         "swap on the paged layout, recompute on the "
                         "legacy timeline (which cannot swap — asking "
                         "for swap there is a config-time error)")
    ap.add_argument("--no-decode-cow", action="store_true",
                    help="don't register decode-completed pages in the "
                         "COW prefix index")
    ap.add_argument("--verify-preempt", action="store_true",
                    help="serve the stream again under the recompute "
                         "oracle AND undisturbed (roomy pool) and assert "
                         "all three token streams are identical (use with "
                         "--f32; requires --preempt-policy swap and a "
                         "pool tight enough to actually preempt)")
    ap.add_argument("--shared-system-prompt", type=float, default=0.0,
                    metavar="RATIO",
                    help="fraction of synthetic prompts extending one "
                         "fixed system prompt (drives COW page sharing)")
    ap.add_argument("--warmup", action="store_true",
                    help="AOT-compile every serving shape (decode step, "
                         "all prefill buckets, page ops, chunk kernel, "
                         "swap-target layouts) before serving")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="stream prompts longer than N in N-token chunks, "
                         "one chunk per engine step between decode ticks "
                         "(0 = whole-prompt prefill)")
    ap.add_argument("--assert-no-recompile", action="store_true",
                    help="with --warmup: fail unless steady-state serving "
                         "performed zero new XLA compilations")
    ap.add_argument("--verify-chunked", action="store_true",
                    help="with --prefill-chunk: serve the stream again "
                         "unchunked and assert identical token streams")
    ap.add_argument("--per-token-prefill", action="store_true",
                    help="disable one-call batched prefill (admission-"
                         "latency baseline)")
    ap.add_argument("--prefill-pack", type=int, default=0,
                    help="pack up to K queued short prompts into ONE "
                         "bucketed prefill call (paged + batched prefill "
                         "only; 0 = off)")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated serving: a prefill-role engine "
                         "seals each prompt's KV pages and hands them to "
                         "a decode-role engine over the transfer-manifest "
                         "protocol (paged layout only)")
    ap.add_argument("--verify-disagg", action="store_true",
                    help="with --disagg: serve the same stream three ways "
                         "— disaggregated, monolithic, and orchestrator-"
                         "fallback (no prefill peer) — and assert all "
                         "three token streams are identical (use with "
                         "--f32)")
    ap.add_argument("--chaos", action="store_true",
                    help="arm the seeded chaos fault plane "
                         "(FaultConfig.chaos(seed=--seed)): sealed-"
                         "payload corruption/truncation, stage stalls, "
                         "handoff drops/delays (--disagg), pool storms")
    ap.add_argument("--chaos-death", type=float, default=0.0,
                    metavar="P",
                    help="with --chaos: per-telemetry-tick probability "
                         "of killing a staged device (capped at one "
                         "death; recovery = spill + replan + swap-in)")
    ap.add_argument("--verify-recovery", action="store_true",
                    help="with --chaos: serve the same stream fault-free "
                         "and assert identical token streams AND every "
                         "injected fault accounted to a recovery "
                         "counter (use with --f32 --no-seal)")
    ap.add_argument("--no-seal", action="store_true")
    ap.add_argument("--topology", default="two-enclave",
                    choices=sorted(TOPOLOGIES),
                    help="trust-domain registry the planner places over")
    ap.add_argument("--delta", type=float, default=LM_SIM_DELTA,
                    help="privacy threshold for untrusted segments")
    ap.add_argument("--require-non-prefix", action="store_true",
                    help="assert the planned PlacementSpec is NOT "
                         "expressible in the legacy trusted-prefix space")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the top-k logits (0 = off)")
    ap.add_argument("--solver", default="dp",
                    choices=["dp", "exhaustive", "beam"])
    ap.add_argument("--space", default="segment",
                    choices=["segment", "prefix"],
                    help="placement search space (segment = PlacementSpec)")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "local", "pipelined"])
    ap.add_argument("--telemetry-interval", type=int, default=4)
    ap.add_argument("--inject-straggler", default=None, metavar="STAGE:FACTOR",
                    help="multiply stage STAGE's measured time by FACTOR")
    ap.add_argument("--verify-swap", action="store_true",
                    help="run twice (with/without straggler) and assert "
                         "identical token streams across the live swap")
    ap.add_argument("--f32", action="store_true",
                    help="run in float32 (used with --verify-swap)")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def _make_config(args):
    max_seq = args.max_seq or (
        args.prompt_len + args.requests * args.arrival_every
        + args.max_new * args.requests // args.slots + args.max_new + 16)
    ec = EngineConfig(
        num_slots=args.slots, num_stages=args.stages,
        num_microbatches=args.microbatches, max_seq=max_seq,
        prompt_capacity=args.prompt_len,
        kv_layout=args.kv_layout, page_size=args.page_size,
        num_pages=args.num_pages, page_policy=args.page_policy,
        prefix_sharing=not args.no_prefix_sharing,
        preempt_policy=args.preempt_policy,
        decode_cow=not args.no_decode_cow,
        request_capacity=args.prompt_len + args.max_new,
        batched_prefill=not args.per_token_prefill,
        prefill_pack=args.prefill_pack,
        seal_boundary=not args.no_seal, solver=args.solver,
        space=args.space, delta=args.delta,
        temperature=args.temperature, top_k=args.top_k,
        telemetry_interval=args.telemetry_interval,
        warmup=args.warmup, prefill_chunk=args.prefill_chunk,
        faults=(FaultConfig.chaos(seed=args.seed,
                                  device_death=args.chaos_death)
                if args.chaos else None))
    backend = None if args.backend == "auto" else args.backend
    rm = TOPOLOGIES[args.topology](args.stages)
    return ec, backend, rm


def _assert_recovery_accounted(st):
    """Every injected fault maps to a named recovery rung or an
    in-progress marker (the tests/test_faults.py accounting property)."""
    inj, rec, pend = st["faults"], st["recovery"], st["faults_pending"]
    assert inj["corrupt_swap"] + inj["truncate_swap"] \
        == rec["unseal_fallback_swap"], (inj, rec)
    assert inj["corrupt_transfer"] + inj["truncate_transfer"] \
        == rec["unseal_fallback_transfer"], (inj, rec)
    assert inj["device_death"] \
        == rec["device_loss_replans"] + (1 if pend["death"] else 0), (inj, rec)
    assert inj["stage_stall"] \
        == rec["stall_replans"] + (1 if pend["stall"] else 0), (inj, rec)
    assert inj["pool_storm"] \
        == rec["storm_reclaims"] + (1 if pend["storm"] else 0), (inj, rec)


def _make_engine(api, params, mesh, args) -> ServingEngine:
    ec, backend, rm = _make_config(args)
    return ServingEngine(api, mesh=mesh, rm=rm, config=ec, params=params,
                         backend=backend)


def _gen_prompts(args, cfg):
    rng = np.random.RandomState(args.seed)
    sys_prompt = rng.randint(0, cfg.vocab_size,
                             size=max(2, args.prompt_len // 2)).tolist()
    prompts = []
    for _ in range(args.requests):
        if rng.rand() < args.shared_system_prompt:
            tail = rng.randint(
                0, cfg.vocab_size,
                size=int(rng.randint(0, args.prompt_len
                                     - len(sys_prompt) + 1))).tolist()
            prompts.append(sys_prompt + tail)
        else:
            prompts.append(rng.randint(
                0, cfg.vocab_size,
                size=int(rng.randint(2, args.prompt_len + 1))).tolist())
    return prompts


def _serve_stream(eng: ServingEngine, args, cfg):
    """Submit a deterministic synthetic arrival stream and drain it."""
    prompts = _gen_prompts(args, cfg)
    reqs = []
    k = 0
    while k < len(prompts) or eng.scheduler.has_work():
        if k < len(prompts) and eng.steps % args.arrival_every == 0:
            reqs.append(eng.submit(prompts[k], args.max_new))
            k += 1
        moved = eng.step()
        if eng.stalled:
            # permanent back-pressure (legacy timeline exhausted): the FIFO
            # head can never run, so later submissions can't either — stop
            # driving gracefully (engine steps are frozen; waiting or
            # submitting more would spin forever)
            break
        if k < len(prompts) and not moved and not eng.scheduler.has_work():
            # idle tick with arrivals pending: admit next immediately
            reqs.append(eng.submit(prompts[k], args.max_new))
            k += 1
    return reqs


def _serve_stream_orch(orch, args, cfg):
    """The orchestrator twin of ``_serve_stream`` (same prompt stream, same
    submission order — so rids, and therefore sampler keystreams, match a
    monolithic run exactly)."""
    prompts = _gen_prompts(args, cfg)
    reqs, k = [], 0
    while k < len(prompts) or orch.has_work():
        if k < len(prompts) and orch.decode.steps % args.arrival_every == 0:
            reqs.append(orch.submit(prompts[k], args.max_new))
            k += 1
        orch.step()
        if orch.decode.stalled and not (
                orch.prefill is not None and orch.prefill.has_work()):
            break
        if k < len(prompts) and not orch.has_work():
            reqs.append(orch.submit(prompts[k], args.max_new))
            k += 1
    return reqs


def _disagg_main(api, params, mesh, args, cfg):
    """--disagg: serve through the prefill/decode orchestrator; with
    --verify-disagg, re-serve monolithically AND through the no-peer
    fallback orchestrator and assert all three streams are identical."""
    from repro.serving import (DisaggOrchestrator, build_disagg,
                               plan_disagg_roles)
    ec, backend, rm = _make_config(args)
    plan = plan_disagg_roles(rm, cfg, prompt_len=max(args.prompt_len, 16),
                             max_new=args.max_new,
                             page_size=args.page_size)
    print(f"role plan: {plan.describe()}")
    orch = build_disagg(api, params=params, config=ec, backend=backend,
                        mesh=mesh, rm=rm)
    print(f"disagg: prefill backend={orch.eng_prefill.backend_kind} "
          f"decode backend={orch.decode.backend_kind} "
          f"kv_layout={orch.decode.kv_layout}")
    reqs = _serve_stream_orch(orch, args, cfg)
    orch.check_invariants()
    st = orch.stats()
    print(f"served {st['completed'] + st['prefill_completed']} requests, "
          f"{st['tokens_out']} tokens ({st['tok_per_s']:.1f} tok/s) "
          f"handoffs={st['handoffs']} "
          f"backpressure={st['backpressure_events']} "
          f"finished_at_prefill={st['prefill_completed']}")
    ps = st["prefill_stats"]
    print(f"prefill side: admissions={ps['admissions']} "
          f"prefill_calls={ps['prefill_calls']} "
          f"transfers_out={ps['transfers_out']} "
          f"packed={ps['packed_admissions']}")
    if reqs:
        print("sample tokens:", reqs[0].generated)

    if args.assert_no_recompile:
        assert args.warmup, "--assert-no-recompile needs --warmup"
        for side, n, stalls in (
                ("decode", st["post_warmup_compiles"], st["compile_stalls"]),
                ("prefill", ps["post_warmup_compiles"],
                 orch.eng_prefill.stats()["compile_stalls"])):
            assert n in (None, 0), \
                f"{side}: {n} XLA compilations after warmup (stalls: " \
                f"{stalls})"
            assert not stalls, (side, stalls)
        print("NO-RECOMPILE OK: zero post-warmup compiles on both roles")

    if args.verify_disagg:
        mono_reqs = _serve_stream(_make_engine(api, params, mesh, args),
                                  args, cfg)
        fb = DisaggOrchestrator(_make_engine(api, params, mesh, args))
        fb_reqs = _serve_stream_orch(fb, args, cfg)
        assert fb.stats()["handoffs"] == 0
        for a, b, c in zip(reqs, mono_reqs, fb_reqs):
            assert a.generated == b.generated == c.generated, \
                f"req {a.rid} diverged across serving modes:\n" \
                f"  disagg     {a.generated}\n  monolithic {b.generated}\n" \
                f"  fallback   {c.generated}"
        print(f"DISAGG-EXACT OK: {len(reqs)} token streams identical "
              f"across disaggregated / monolithic / fallback "
              f"({st['handoffs']} sealed handoffs)")

    if args.chaos:
        dst = orch.decode.stats()
        print(f"chaos: injected={orch.decode.faults.snapshot()} "
              f"recovery={ {k: v for k, v in dst['recovery'].items() if v} }"
              f" in_flight={st['in_flight_handoffs']}")
    if args.verify_recovery:
        assert args.chaos, "--verify-recovery needs --chaos"
        dst = orch.decode.stats()
        total = orch.decode.faults.total_injected() + \
            orch.eng_prefill.faults.total_injected()
        assert total > 0, "chaos armed but no fault landed"
        assert st["in_flight_handoffs"] == 0
        calm = copy.copy(args)
        calm.chaos = False
        ec2, backend2, rm2 = _make_config(calm)
        orch2 = build_disagg(api, params=params, config=ec2,
                             backend=backend2, mesh=mesh, rm=rm2)
        reqs_calm = _serve_stream_orch(orch2, calm, cfg)
        for a, b in zip(reqs, reqs_calm):
            assert a.generated == b.generated, \
                f"req {a.rid} diverged under chaos:\n" \
                f"  chaotic    {a.generated}\n  fault-free {b.generated}"
        assert not dst["failed_requests"], dst["failed_requests"]
        _assert_recovery_accounted(dst)
        _assert_recovery_accounted(orch.eng_prefill.stats())
        print(f"RECOVERY-EXACT OK: {len(reqs)} token streams identical "
              f"under {total} injected faults across both roles "
              f"({ {k: v for k, v in dst['recovery'].items() if v} })")
    return st


def main(argv=None):
    args = build_parser().parse_args(argv)
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    if args.f32:
        import repro.models.layers as L
        L.DEFAULT_DTYPE = jnp.float32

    mesh = None
    if args.backend != "local" and pipelined_backend_available():
        dims = tuple(int(x) for x in args.mesh.split("x"))
        mesh = make_mesh(dims, ("pod", "data")[:len(dims)])

    api = build_model(cfg, max_seq=args.max_seq or 512)
    params = api.init(jax.random.PRNGKey(0))
    if args.f32:
        params = jax.tree.map(
            lambda x: x.astype(jnp.float32)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, params)

    if args.disagg:
        return _disagg_main(api, params, mesh, args, cfg)

    inject = None
    if args.inject_straggler:
        s, f = args.inject_straggler.split(":")
        inject = (int(s), float(f))

    def one_run(with_inject: bool, run_args=None):
        a = run_args or args
        eng = _make_engine(api, params, mesh, a)
        if with_inject and inject:
            eng.telemetry.inject(*inject)
        print(f"backend={eng.backend_kind} kv_layout={eng.kv_layout} "
              f"stage_blocks={eng.stage_blocks} "
              f"placement={eng.spec.describe()}")
        if eng.warmed:
            print(f"warmup: {eng.warmup_s:.2f}s, "
                  f"{sum(len(f.signatures) for f in eng.aot.fns.values())} "
                  f"signatures over {len(eng.aot.fns)} functions")
        if args.require_non_prefix:
            graph = eng.rm.resource_graph()
            assert not eng.spec.is_prefix(graph), \
                f"planned placement is prefix-expressible: " \
                f"{eng.spec.describe()}"
            print("NON-PREFIX OK: placement not expressible in the "
                  "trusted-prefix space")
        reqs = _serve_stream(eng, a, cfg)
        for e in eng.events:
            if e.kind in ("replan", "swap", "swap_skipped"):
                print(f"  step {e.step}: {e.kind} {e.detail}")
        st = eng.stats()
        print(f"served {st['completed']} requests, {st['tokens_out']} tokens "
              f"in {st['decode_wall_s']:.2f}s decode "
              f"({st['tok_per_s']:.1f} tok/s), replans={st['replans']} "
              f"swaps={st['swaps']} final_blocks={st['stage_blocks']} "
              f"prefill_calls={st['prefill_calls']} "
              f"admission_p50={st.get('admission_p50_ms', 0):.1f}ms")
        if st.get("swap_outs") or st.get("preemptions"):
            print(f"preempt: policy={st['preempt_policy']} "
                  f"preemptions={st['preemptions']} "
                  f"swap_outs={st['swap_outs']} swap_ins={st['swap_ins']} "
                  f"fallbacks={st['swap_fallbacks']}")
        if st.get("prefill_chunk"):
            print(f"chunked prefill: {st['chunked_admissions']} admissions "
                  f"in {st['prefill_chunks']} chunks of "
                  f"{st['prefill_chunk']} tokens")
        if eng.warmed:
            print(f"post-warmup compiles: {st['post_warmup_compiles']} "
                  f"stalls: {st['compile_stalls']}")
        if eng.faults is not None:
            print(f"chaos: injected={eng.faults.snapshot()} "
                  f"recovery={ {k: v for k, v in st['recovery'].items() if v} }"
                  f" pending={st['faults_pending']} "
                  f"failed={st['failed_requests']}")
        return eng, reqs

    eng, reqs = one_run(with_inject=True)
    st = eng.stats()
    if reqs:
        print("sample tokens:", reqs[0].generated)

    if args.assert_no_recompile:
        # checked BEFORE any --verify-* rerun: the compile counter is
        # process-global, so a second engine's warmup would land in this
        # engine's post-freeze window
        assert args.warmup, "--assert-no-recompile needs --warmup"
        n = st["post_warmup_compiles"]
        # None = the compile monitor could not hook this jax version; the
        # registry's own stall ledger still covers managed functions
        assert n in (None, 0), \
            f"{n} XLA compilations after warmup; stalls: " \
            f"{st['compile_stalls']}"
        assert not st["compile_stalls"], st["compile_stalls"]
        print(f"NO-RECOMPILE OK: post_warmup_compiles="
              f"{'unavailable' if n is None else n}, 0 stalls")

    if args.verify_swap:
        assert args.no_seal, "--verify-swap needs --no-seal (see docstring)"
        assert inject, "--verify-swap needs --inject-straggler"
        eng2, reqs2 = one_run(with_inject=False)
        assert eng.swaps >= 1, \
            f"straggler injection produced no live swap (events: " \
            f"{[e.kind for e in eng.events]})"
        for a, b in zip(reqs, reqs2):
            assert a.generated == b.generated, \
                f"req {a.rid} diverged across live swap:\n  {a.generated}\n" \
                f"  {b.generated}"
        print(f"SWAP-EXACT OK: {len(reqs)} token streams identical across "
              f"live re-plan ({eng.stats()['stage_blocks']} vs "
              f"{eng2.stats()['stage_blocks']})")

    if args.verify_chunked:
        assert args.prefill_chunk > 0, \
            "--verify-chunked needs --prefill-chunk N"
        unchunked = copy.copy(args)
        unchunked.prefill_chunk = 0
        eng3, reqs3 = one_run(with_inject=True, run_args=unchunked)
        assert eng.stats()["chunked_admissions"] > 0, \
            "no prompt exceeded --prefill-chunk: nothing verified " \
            "(raise --prompt-len or lower --prefill-chunk)"
        for a, b in zip(reqs, reqs3):
            assert a.generated == b.generated, \
                f"req {a.rid} diverged under chunked prefill:\n" \
                f"  {a.generated}\n  {b.generated}"
        print(f"CHUNK-EXACT OK: {len(reqs)} token streams identical, "
              f"chunked ({args.prefill_chunk}) vs one-shot prefill")

    if args.verify_preempt:
        assert args.preempt_policy == "swap", \
            "--verify-preempt compares the swap path against its oracles"
        assert st.get("swap_outs", 0) > 0, \
            "pool never swap-preempted: nothing verified " \
            "(shrink --num-pages or raise --requests)"
        oracle = copy.copy(args)
        oracle.preempt_policy = "recompute"
        _, reqs_rc = one_run(with_inject=True, run_args=oracle)
        roomy = copy.copy(args)
        roomy.num_pages = 0      # all slots at full capacity: no preemption
        eng_ud, reqs_ud = one_run(with_inject=True, run_args=roomy)
        assert eng_ud.stats().get("preemptions", 0) == 0
        for a, b, c in zip(reqs, reqs_rc, reqs_ud):
            assert a.generated == b.generated == c.generated, \
                f"req {a.rid} diverged across preempt policies:\n" \
                f"  swap      {a.generated}\n  recompute {b.generated}\n" \
                f"  undisturbed {c.generated}"
        print(f"PREEMPT-EXACT OK: {len(reqs)} token streams identical "
              f"across swap resume / recompute oracle / undisturbed "
              f"({st['swap_outs']} swap-outs)")

    if args.verify_recovery:
        assert args.chaos, "--verify-recovery needs --chaos"
        total = eng.faults.total_injected()
        assert total > 0, \
            "chaos armed but no fault landed: nothing verified (raise " \
            "--requests, shrink --num-pages, or set --chaos-death)"
        calm = copy.copy(args)
        calm.chaos = False
        _, reqs_calm = one_run(with_inject=True, run_args=calm)
        for a, b in zip(reqs, reqs_calm):
            assert a.generated == b.generated, \
                f"req {a.rid} diverged under chaos:\n" \
                f"  chaotic    {a.generated}\n  fault-free {b.generated}"
        assert not st["failed_requests"], st["failed_requests"]
        _assert_recovery_accounted(st)
        print(f"RECOVERY-EXACT OK: {len(reqs)} token streams identical "
              f"under {total} injected faults, every fault accounted "
              f"({ {k: v for k, v in st['recovery'].items() if v} })")
    return st


if __name__ == "__main__":
    main()
