"""Serving launcher: Serdab pipelined decode across trust-domain pods.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \\
      --mesh 2x2 --stages 2 --microbatches 4 --requests 3

Plans stage boundaries with the placement solver over the registered trust
domains, prefills a batch of requests, then streams pipelined decode steps
with sealed stage boundaries.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced as reduce_cfg
from repro.core.planner import profiles_from_arch
from repro.core.privacy import LM_SIM_DELTA
from repro.enclave.domain import two_enclave_manager
from repro.launch.mesh import make_mesh
from repro.models.api import build_model
from repro.runtime.pipeline import PipelinedDecoder, pipeline_applicable


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="2x1", help="pod x data")
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--requests", type=int, default=4, help="decode steps")
    ap.add_argument("--no-seal", action="store_true")
    ap.add_argument("--solver", default="dp",
                    choices=["dp", "exhaustive", "beam"])
    ap.add_argument("--even-stages", action="store_true",
                    help="ignore planned boundaries; split blocks evenly")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    max_seq = args.prompt_len + args.requests + 1

    # --- Serdab plan over the trust domains -----------------------------
    rm = two_enclave_manager()
    profiles = profiles_from_arch(cfg, seq_len=1)
    res = rm.plan(profiles, n=10_000, delta=LM_SIM_DELTA, solver=args.solver)
    best = res.best
    print("placement:", best.placement.describe(),
          f"(bottleneck {best.bottleneck * 1e6:.1f} us/frame, "
          f"{res.solver}: {res.n_feasible} feasible / {res.n_pruned} pruned "
          f"in {res.wall_time_s * 1e3:.1f} ms)")
    stage_blocks = None
    planned = best.placement.stage_sizes()
    if not args.even_stages and len(planned) == args.stages:
        stage_blocks = planned
        print("stage boundaries from plan:", "/".join(map(str, planned)))
    elif not args.even_stages:
        print(f"plan wants {len(planned)} stages but --stages={args.stages}; "
              f"falling back to even split")

    dims = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(dims, ("pod", "data")[:len(dims)])
    api = build_model(cfg, max_seq=max_seq)
    assert pipeline_applicable(api), f"{cfg.name}: pipelined serve unsupported"

    params = api.init(jax.random.PRNGKey(0))
    key = jnp.uint32(0xC0FFEE)

    with jax.set_mesh(mesh):
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size, jnp.int32)
        logits, cache = jax.jit(api.prefill_fn)(params, {"tokens": prompts})
        # widen cache to max_seq
        seg = api.model.segments[0].name
        pad = max_seq - args.prompt_len
        cache[seg] = jax.tree.map(
            lambda a: jnp.pad(a, [(0, 0)] * 3 + [(0, pad)] + [(0, 0)])
            if a.ndim == 5 else a, cache[seg])

        dec = PipelinedDecoder(api, mesh, num_stages=args.stages,
                               num_microbatches=args.microbatches,
                               seal_boundary=not args.no_seal,
                               stage_blocks=stage_blocks)
        # stage params AND cache once outside the decode loop (uneven
        # staging is a gather; the cache would round-trip twice per token)
        staged_params = dec.stage_params(params)
        staged_cache = dec.stage_cache(cache)
        step = jax.jit(dec.build(prestaged_params=True,
                                 prestaged_cache=True))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        generated = [tok]
        t0 = time.time()
        for i in range(args.requests):
            logits, staged_cache = step(staged_params, staged_cache,
                                        {"tokens": tok}, key + i)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            generated.append(tok)
        jax.block_until_ready(tok)
        dt = time.time() - t0
    out = np.concatenate([np.asarray(t) for t in generated], axis=1)
    print(f"decoded {args.requests} steps x batch {args.batch} "
          f"in {dt:.2f}s ({args.requests * args.batch / dt:.1f} tok/s)")
    print("sample tokens:", out[0].tolist())
    return out


if __name__ == "__main__":
    main()
