"""Fault tolerance for the serving pipeline: heartbeats, straggler
detection, and Serdab re-planning (the paper's 'online re-partitioning when
profiling information deviates from predictions', Sec. V).

Planning goes through ``ResourceManager.plan()/replan_on_failure()`` (the
planner's re-planning layer, DESIGN.md §Planner): cost tables are cached on
the manager, so a failure-driven re-solve only pays for the solver pass, and
the resulting (possibly uneven) stage boundaries feed straight into
``PipelinedDecoder(stage_blocks=evaluation.placement.stage_sizes())``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

from repro.core.planner import Evaluation, LayerProfile, SolveResult
from repro.enclave.domain import ResourceManager


@dataclasses.dataclass
class HeartbeatMonitor:
    rm: ResourceManager
    timeout_s: float = 10.0

    def sweep(self, now: Optional[float] = None) -> List[str]:
        """Marks domains whose heartbeat is stale; returns their names."""
        now = now if now is not None else time.monotonic()
        dead = []
        for d in self.rm.domains():
            if d.healthy and now - d.last_heartbeat > self.timeout_s:
                self.rm.mark_unhealthy(d.name)
                dead.append(d.name)
        return dead


@dataclasses.dataclass
class OnlineReplanner:
    """Watches per-stage observed rates and re-runs the placement solver
    when observation deviates from prediction (or a domain dies)."""

    rm: ResourceManager
    profiles: Sequence[LayerProfile]
    n: int
    delta: float
    deviation_threshold: float = 1.5
    solver: str = "dp"
    current: Optional[Evaluation] = None
    last_result: Optional[SolveResult] = None
    replans: int = 0

    def plan(self) -> Evaluation:
        res = self.rm.plan(self.profiles, n=self.n, delta=self.delta,
                           solver=self.solver)
        self.last_result = res
        self.current = res.best
        return res.best

    def observe(self, stage_times: Dict[str, float]) -> Optional[Evaluation]:
        """stage_times: measured per-device stage time. Re-plans when any
        device is deviation_threshold x slower than the plan predicted, or
        when the plan references a dead domain."""
        if self.current is None:
            return self.plan()
        predicted = {s.device: t for s, t in
                     zip(self.current.placement.stages, self.current.stage_times)}
        healthy = {d.name for d in self.rm.healthy_domains()}
        dead = [s.device for s in self.current.placement.stages
                if s.device not in healthy]
        needs_replan = bool(dead)
        for dev, obs in stage_times.items():
            pred = predicted.get(dev)
            if pred and obs > self.deviation_threshold * pred:
                # fold the observation into the device profile (derate it)
                d = self.rm.get(dev)
                derate = pred / obs
                d.device = dataclasses.replace(
                    d.device, flops_per_s=d.device.flops_per_s * derate,
                    mem_bw=d.device.mem_bw * derate)
                needs_replan = True
        if needs_replan:
            self.replans += 1
            if dead:
                res = self.rm.replan_on_failure(
                    dead, profiles=self.profiles, n=self.n, delta=self.delta,
                    solver=self.solver)
                self.last_result = res
                self.current = res.best
                return res.best
            return self.plan()
        return None
