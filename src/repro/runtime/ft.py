"""Fault tolerance for the serving pipeline: heartbeats, straggler
detection, and Serdab re-planning (the paper's 'online re-partitioning when
profiling information deviates from predictions', Sec. V).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.placement import (Evaluation, LayerProfile, ResourceGraph,
                                  solve)
from repro.enclave.domain import ResourceManager


@dataclasses.dataclass
class HeartbeatMonitor:
    rm: ResourceManager
    timeout_s: float = 10.0

    def sweep(self, now: Optional[float] = None) -> List[str]:
        """Marks domains whose heartbeat is stale; returns their names."""
        now = now if now is not None else time.monotonic()
        dead = []
        for d in self.rm.domains():
            if d.healthy and now - d.last_heartbeat > self.timeout_s:
                self.rm.mark_unhealthy(d.name)
                dead.append(d.name)
        return dead


@dataclasses.dataclass
class OnlineReplanner:
    """Watches per-stage observed rates and re-runs the placement solver
    when observation deviates from prediction (or a domain dies)."""

    rm: ResourceManager
    profiles: Sequence[LayerProfile]
    n: int
    delta: float
    deviation_threshold: float = 1.5
    current: Optional[Evaluation] = None
    replans: int = 0

    def plan(self) -> Evaluation:
        graph = self.rm.resource_graph()
        best, _ = solve(self.profiles, graph, n=self.n, delta=self.delta)
        self.current = best
        return best

    def observe(self, stage_times: Dict[str, float]) -> Optional[Evaluation]:
        """stage_times: measured per-device stage time. Re-plans when any
        device is deviation_threshold x slower than the plan predicted, or
        when the plan references a dead domain."""
        if self.current is None:
            return self.plan()
        predicted = {s.device: t for s, t in
                     zip(self.current.placement.stages, self.current.stage_times)}
        healthy = {d.name for d in self.rm.healthy_domains()}
        needs_replan = any(s.device not in healthy
                           for s in self.current.placement.stages)
        for dev, obs in stage_times.items():
            pred = predicted.get(dev)
            if pred and obs > self.deviation_threshold * pred:
                # fold the observation into the device profile (derate it)
                d = self.rm.get(dev)
                derate = pred / obs
                d.device = dataclasses.replace(
                    d.device, flops_per_s=d.device.flops_per_s * derate,
                    mem_bw=d.device.mem_bw * derate)
                needs_replan = True
        if needs_replan:
            self.replans += 1
            return self.plan()
        return None
