"""Fault tolerance for the serving pipeline: heartbeats, straggler
detection, and Serdab re-planning (the paper's 'online re-partitioning when
profiling information deviates from predictions', Sec. V).

Planning goes through ``ResourceManager.plan()/replan_on_failure()`` (the
planner's re-planning layer, DESIGN.md §Planner), which return a
``PlacementSpec`` — the segment-graph placement the runtime consumes
directly (``PipelinedDecoder.from_spec`` / ``ServingEngine``). Cost tables
are cached on the manager, so a failure-driven re-solve only pays for the
solver pass. Failed devices drop out of the resource graph before the
re-solve, so exclusion holds wherever the device sat in the segment chain —
mid-chain untrusted segments included, not just a trailing suffix.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Mapping, Optional, Sequence, Tuple, Union

from repro.core.planner import (Evaluation, InfeasibleError, LayerProfile,
                                PlacementSpec, SolveResult)
from repro.enclave.domain import ResourceManager

StageKey = Union[int, Tuple[str, int], str]


@dataclasses.dataclass
class HeartbeatMonitor:
    rm: ResourceManager
    timeout_s: float = 10.0

    def sweep(self, now: Optional[float] = None) -> List[str]:
        """Marks domains whose heartbeat is stale; returns their names."""
        now = now if now is not None else time.monotonic()
        dead = []
        for d in self.rm.domains():
            if d.healthy and now - d.last_heartbeat > self.timeout_s:
                self.rm.mark_unhealthy(d.name)
                dead.append(d.name)
        return dead


@dataclasses.dataclass
class OnlineReplanner:
    """Watches per-stage observed rates and re-runs the placement solver
    when observation deviates from prediction (or a domain dies).

    ``plan()``/``observe()`` return the new ``PlacementSpec``;
    ``self.current`` keeps the matching ``Evaluation`` (predicted stage
    times drive deviation detection), ``self.current_spec`` the spec."""

    rm: ResourceManager
    profiles: Sequence[LayerProfile]
    n: int
    delta: float
    deviation_threshold: float = 1.5
    derate_floor: float = 0.05          # cumulative derate never drops below
    solver: str = "dp"
    space: str = "segment"              # PlacementSpec search space
    min_stages: Optional[int] = None    # serving: use every pipeline pod
    current: Optional[Evaluation] = None
    current_spec: Optional[PlacementSpec] = None
    last_result: Optional[SolveResult] = None
    replans: int = 0
    # failure-driven re-solves (dead domain excluded) vs deviation-driven
    # ones, and every domain ever excluded — the chaos fault plane's
    # property test attributes each injected device death to exactly one
    # failure_replan whose excluded set names the corpse
    failure_replans: int = 0
    excluded_devices: List[str] = dataclasses.field(default_factory=list)

    def _adopt(self, spec: PlacementSpec) -> PlacementSpec:
        self.last_result = self.rm.last_plan
        self.current = self.rm.last_plan.best
        self.current_spec = spec
        return spec

    def plan(self) -> PlacementSpec:
        spec = self.rm.plan(self.profiles, n=self.n, delta=self.delta,
                            solver=self.solver, space=self.space,
                            min_stages=self.min_stages)
        return self._adopt(spec)

    def _resolve(self, key: StageKey, predicted) -> Optional[Tuple[str, int]]:
        """Normalize an observation key to (device, stage_idx). A bare device
        name (legacy callers) matches that device's slowest predicted stage —
        NOT silently the last one, which dropped observations when a device
        hosted several stages."""
        stages = self.current.placement.stages
        if isinstance(key, tuple):
            return key if key in predicted else None
        if isinstance(key, int):
            return (stages[key].device, key) if 0 <= key < len(stages) else None
        mine = [k for k in predicted if k[0] == key]
        return max(mine, key=lambda k: predicted[k]) if mine else None

    def observe(self, stage_times: Mapping[StageKey, float]
                ) -> Optional[PlacementSpec]:
        """stage_times: measured per-stage wall time, keyed by stage index,
        ``(device, stage_idx)``, or device name (legacy). Re-plans when any
        stage runs deviation_threshold x slower than the plan predicted, or
        when the plan references a dead domain — wherever in the segment
        chain the dead device sat. Deviations derate the hosting device's
        profile through ``ResourceManager.derate`` — cumulative and floored,
        so repeated misses cannot drive ``flops_per_s`` to zero."""
        if self.current is None:
            return self.plan()
        stages = self.current.placement.stages
        predicted = {(s.device, i): t for i, (s, t) in
                     enumerate(zip(stages, self.current.stage_times))}
        healthy = {d.name for d in self.rm.healthy_domains()}
        dead = [s.device for s in stages if s.device not in healthy]
        needs_replan = bool(dead)
        for key, obs in stage_times.items():
            k = self._resolve(key, predicted)
            pred = predicted.get(k) if k is not None else None
            if pred and obs > self.deviation_threshold * pred:
                self.rm.derate(k[0], pred / obs, floor=self.derate_floor)
                needs_replan = True
        if needs_replan:
            self.replans += 1
            if dead:
                self.failure_replans += 1
                self.excluded_devices.extend(
                    d for d in dead if d not in self.excluded_devices)
                try:
                    spec = self.rm.replan_on_failure(
                        dead, profiles=self.profiles, n=self.n,
                        delta=self.delta, solver=self.solver,
                        space=self.space)
                except InfeasibleError:
                    if self.min_stages is None:
                        raise
                    # not enough survivors for the stage floor: best effort
                    spec = self.rm.replan_on_failure(
                        dead, profiles=self.profiles, n=self.n,
                        delta=self.delta, solver=self.solver,
                        space=self.space, min_stages=None)
                return self._adopt(spec)
            return self.plan()
        return None
