"""Serdab pipelined serving over the ``pod`` mesh axis.

This is the paper's core mechanism as a first-class runtime feature: the
block stack is split into ``num_stages`` contiguous stages (boundaries from
the placement solver), stage s lives on pod s, and a stream of decode
microbatches rotates through the stages GPipe-style — while pod 1 decodes
microbatch m for blocks [B/2, B), pod 0 is already decoding microbatch m+1
for blocks [0, B/2). Boundary activations are sealed (int8 quantize +
keystream XOR — kernels/seal.py) before crossing the DCN, exactly the
paper's enclave-to-enclave discipline, and the quantization doubles as 4x
boundary compression.

Implementation: ``jax.shard_map`` manual over {pod} only — data/model axes
stay GSPMD-managed inside each stage, so TP/EP/sequence-sharded caches
compose with pipelining. The tick loop is a ``lax.scan``; communication is
one ``ppermute`` ring per tick.

Applicability: any model whose body is ONE homogeneous scanned segment
(dense, VLM, Qwen-MoE, xLSTM, Hymba). Moonshot's dense stem and Whisper's
encoder make them two-segment models — they serve multi-pod via batch
sharding instead (DESIGN.md §Arch-applicability).

Stage boundaries need not be even: ``stage_blocks`` takes the solver's
per-stage block counts (e.g. 28 blocks as 10/9/9). Uneven stages are padded
to the widest stage; padded slots replicate a real block's params/cache and
are masked out of the scan, so logits match the unpipelined decode path
exactly (DESIGN.md §Planner).

Compile-stability contract (DESIGN.md §AOT warmup & chunked prefill): a
PipelinedDecoder's jitted entry points — ``build()``'s step,
``build_stage_probe()``'s probe and the serving backends' chunk closure —
are shape-stable for a FIXED ``stage_blocks`` layout, so the engine's
``warmup()`` can precompile them and a steady-state serve dispatches with
zero new XLA compilations. Two sharp edges the serving layer accounts for:
(1) shard_map state arrays change *sharding* between the first call
(fresh, unsharded ``init_paged_cache`` output) and steady state
(pod-sharded step output), and jit's dispatch cache keys on
(shape, sharding) — both variants must be warmed; (2) ``restage_cache``'s
composed gather is shaped by the specific (old layout, new layout) PAIR —
the warmup tour covers planned↔target pairs, and the backends lazily
AOT-warm and memoize any other pair on first use (keyed by the pair in the
per-layout decoder cache), so a chain of swaps between two non-planned
layouts pays at most one wall-clock warm per pair and NO recorded compile
stall on repeats. Decoders themselves are cached per layout by the
backends (``_layouts``): rebuilding a decoder for a layout already seen
would discard the warmed dispatch caches with it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.enclave import sealing
from repro.models.api import ModelAPI
from repro.models import layers as L
from repro.sharding import rules as R


def pipeline_applicable(api: ModelAPI) -> bool:
    model = api.model
    return (hasattr(model, "segments") and len(model.segments) == 1)


def _batch_slice(tree, start, size, axis=1):
    return jax.tree.map(
        lambda x: jax.lax.dynamic_slice_in_dim(x, start, size, axis=axis), tree)


def _batch_update(tree, update, start, axis=1):
    return jax.tree.map(
        lambda x, u: jax.lax.dynamic_update_slice_in_dim(x, u, start, axis=axis),
        tree, update)


@dataclasses.dataclass
class PipelinedDecoder:
    """Builds a jit-able pipelined decode step for one mesh."""

    api: ModelAPI
    mesh: Mesh
    num_stages: int
    num_microbatches: int
    seal_boundary: bool = True
    use_kernel: bool = False            # Pallas path on TPU
    stage_blocks: Optional[Sequence[int]] = None   # per-stage block counts
    stage_devices: Optional[Sequence[str]] = None  # per-stage device names

    @classmethod
    def from_spec(cls, api: ModelAPI, mesh: Mesh, spec,
                  num_microbatches: int, **kw) -> "PipelinedDecoder":
        """Build a decoder directly from a planner ``PlacementSpec``: stage s
        runs segment s (``spec.segments[s]``) on pod s. The spec's device
        order is the pipeline order — non-prefix placements (untrusted
        segments interleaved mid-chain) execute exactly like prefix ones;
        the trust domain only changes what the cost model charged and which
        boundaries the sealing discipline covers."""
        n = api.model.segments[0].n
        assert spec.num_layers == n, (spec.num_layers, n)
        return cls(api, mesh, num_stages=spec.num_segments,
                   num_microbatches=num_microbatches,
                   stage_blocks=spec.stage_sizes(),
                   stage_devices=spec.devices(), **kw)

    def __post_init__(self):
        model = self.api.model
        assert pipeline_applicable(self.api), \
            "pipelined serve needs a single homogeneous segment"
        self.seg = model.segments[0]
        S = self.num_stages
        if self.stage_blocks is None:
            assert self.seg.n % S == 0, \
                f"{self.seg.n} blocks not divisible into {S} stages; " \
                f"pass stage_blocks= for uneven boundaries"
            counts = (self.seg.n // S,) * S
        else:
            counts = tuple(int(c) for c in self.stage_blocks)
            assert len(counts) == S, (counts, S)
            assert all(c > 0 for c in counts), counts
            assert sum(counts) == self.seg.n, (counts, self.seg.n)
        if self.stage_devices is not None:
            self.stage_devices = tuple(self.stage_devices)
            assert len(self.stage_devices) == S, (self.stage_devices, S)
        self.stage_counts = counts
        self.bps = max(counts)          # padded per-stage block count
        self.uniform = len(set(counts)) == 1
        starts = np.concatenate([[0], np.cumsum(counts)])
        # gather: staged slot (s, j) holds block starts[s] + min(j, c_s - 1);
        # padded slots replicate the stage's last block (finite values, then
        # masked out of the scan)
        self._gather_idx = np.stack(
            [starts[s] + np.minimum(np.arange(self.bps), counts[s] - 1)
             for s in range(S)]).reshape(-1)
        # scatter: block i lives at staged slot stage(i) * bps + offset
        self._scatter_idx = np.concatenate(
            [s * self.bps + np.arange(counts[s]) for s in range(S)])
        self._mask = np.stack(
            [np.arange(self.bps) < counts[s] for s in range(S)])

    # -- parameter / cache reshaping (leading stage dim, sharded over pod) --
    def _stage_tree(self, tree):
        """[n_blocks, ...] leaves -> [num_stages, bps, ...] (gather-padded
        when stages are uneven, plain reshape when even)."""
        S, bps = self.num_stages, self.bps
        if self.uniform:
            return jax.tree.map(
                lambda x: x.reshape((S, bps) + x.shape[1:]), tree)
        idx = jnp.asarray(self._gather_idx)
        return jax.tree.map(
            lambda x: jnp.take(x, idx, axis=0).reshape(
                (S, bps) + x.shape[1:]), tree)

    def stage_params(self, params):
        seg = dict(params)
        seg[self.seg.name] = self._stage_tree(params[self.seg.name])
        return seg

    def stage_cache(self, cache):
        return self._stage_tree(cache[self.seg.name]), cache["len"]

    def unstage_cache(self, staged, new_len):
        S, bps = self.num_stages, self.bps
        if self.uniform:
            body = jax.tree.map(
                lambda x: x.reshape((self.seg.n,) + x.shape[2:]), staged)
        else:
            idx = jnp.asarray(self._scatter_idx)
            body = jax.tree.map(
                lambda x: jnp.take(
                    x.reshape((S * bps,) + x.shape[2:]), idx, axis=0), staged)
        return {self.seg.name: body, "len": new_len}

    def restage_cache(self, staged_cache, new_dec: "PipelinedDecoder"):
        """Migrate a prestaged cache from this decoder's stage layout to
        ``new_dec``'s (a live re-plan swap). Equivalent to unstage followed by
        ``new_dec.stage_cache`` but composes the scatter and gather into a
        single ``jnp.take`` per leaf, so in-flight KV state moves to the new
        boundaries without a host round-trip. Accepts the prestaged tuple
        ``(staged, len)`` or ``(staged, len, start)`` and returns the same
        arity."""
        assert new_dec.seg.n == self.seg.n, (new_dec.seg.n, self.seg.n)
        body, *rest = staged_cache
        S2, bps2 = new_dec.num_stages, new_dec.bps
        idx = jnp.asarray(self._scatter_idx[new_dec._gather_idx])
        new_body = jax.tree.map(
            lambda x: jnp.take(
                x.reshape((self.num_stages * self.bps,) + x.shape[2:]),
                idx, axis=0).reshape((S2, bps2) + x.shape[2:]), body)
        return (new_body, *rest)

    # -- specs ---------------------------------------------------------------
    def _param_specs_tree(self, staged):
        def spec(path_has_stage, x):
            if path_has_stage:
                return P("pod", *([None] * (x.ndim - 1)))
            return P(*([None] * x.ndim))
        return {k: jax.tree.map(functools.partial(spec, k == self.seg.name), v)
                for k, v in staged.items()}

    # -- one stage's block scan (shared by the tick loop and the telemetry
    # -- stage probe) --------------------------------------------------------
    def _stage_run(self, blk_params, blk_cache, blk_mask, x, cache_len,
                   start=None, paged=None):
        cfg, seg = self.api.cfg, self.seg
        if paged is not None:
            # paged cache: per-row 0-based positions from seq_lens; the
            # block cache is the stage's slice of the shared page pools
            _, sl_mb = paged
            positions = sl_mb[:, None]
            pos3 = None
            if cfg.pos_type == "mrope":
                pos3 = jnp.tile(sl_mb[:, None, None], (1, 1, 3))
            kw = {"paged": paged, "paged_kernel": self.use_kernel}
        else:
            positions = jnp.full((1, 1), cache_len, jnp.int32)
            pos3 = None
            if cfg.pos_type == "mrope":
                pos3 = jnp.full((x.shape[0], 1, 3), cache_len, jnp.int32)
            kw = {} if start is None else {"start": start}

        def step(carry, xs):
            p, c, m = xs
            out, new_c = seg.apply_fn(p, carry, positions, mode="decode",
                                      cache=c, cache_len=cache_len,
                                      pos3=pos3, **kw)
            # padded slots (uneven stages) pass the carry through and
            # leave their (replicated) cache untouched
            out = jnp.where(m, out, carry)
            new_c = jax.tree.map(lambda a, b: jnp.where(m, a, b),
                                 new_c, c)
            return out, new_c

        return jax.lax.scan(step, x, (blk_params, blk_cache, blk_mask))

    def build_stage_probe(self, paged: bool = False):
        """A jit-able single-stage runner for per-stage wall-time telemetry:
        ``probe(blk_params, blk_cache, blk_mask, x, cache_len)`` executes one
        stage's block scan exactly as a pipeline tick would (minus seal /
        ppermute) so the host can time each stage independently. The caller
        slices stage s out of the prestaged trees (``tree[s]``) and times
        ``jax.block_until_ready(probe(...))``. With ``paged=True`` the
        signature is ``probe(blk_params, blk_pool, blk_mask, x, bt, sl)``
        (whole-pool stage slice, block table + seq_lens for the probed
        rows)."""
        if paged:
            def probe(blk_params, blk_cache, blk_mask, x, bt, sl):
                h, _ = self._stage_run(blk_params, blk_cache, blk_mask, x,
                                       None, paged=(bt, sl))
                return h
        else:
            def probe(blk_params, blk_cache, blk_mask, x, cache_len):
                h, _ = self._stage_run(blk_params, blk_cache, blk_mask, x,
                                       cache_len)
                return h
        return jax.jit(probe)

    # -- the step -------------------------------------------------------------
    def build(self, prestaged_params: bool = False,
              prestaged_cache: bool = False, per_slot_start: bool = False,
              paged: bool = False):
        """per_slot_start: the cache argument becomes a 3-tuple
        ``(staged, cache_len, start)`` with ``start`` a per-slot [B] int32 of
        first-valid absolute positions (continuous-batching mask); implies
        ``prestaged_cache``.

        paged: the cache argument is ``(staged_pools, block_tables,
        seq_lens)`` — prestaged per-layer page pools (stage-major, pod
        sharded; *no* batch dim: pages are shared, block tables say which
        rows own which pages) plus the per-slot [B, MP] block tables and
        [B] seq_lens, replicated over pods. Every microbatch's stage scan
        scatters its rows' new tokens into disjoint pages of the same pool,
        so the pool is carried whole across ticks instead of batch-sliced;
        warm-up/drain ticks are masked out before committing (their
        boundary activations are garbage). Positions are per-row 0-based —
        the continuous-batching ``start`` mask is unnecessary by
        construction.

        Demand paging / COW contract (DESIGN.md §Demand paging): block
        tables may reference ref-counted pages shared across rows or
        frozen in the engine's prefix index. The decoder itself never
        needs to know — the engine guarantees, before every step, that
        each row's *next write position* is backed by a private
        (refcount-1) page, forking shared pages host-side first; reads
        gather freely across shared pages. ``restage_cache`` migration is
        refcount-oblivious by the same token: page ids are stable across
        a boundary swap (only the layer→stage layout of the pools moves),
        so host-side refcounts and block tables ride along unchanged."""
        api, seg, S = self.api, self.seg, self.num_stages
        nm, bps = self.num_microbatches, self.bps
        cfg = api.cfg
        model = api.model
        mesh = self.mesh
        seal_on = self.seal_boundary
        use_kernel = self.use_kernel
        if per_slot_start:
            assert prestaged_cache, "per_slot_start implies prestaged_cache"
        assert not (per_slot_start and paged)
        stage_run = self._stage_run

        def pipeline_body(params, staged_cache, stage_mask, tokens, starts,
                          cache_len, key):
            """Runs manual over pod. tokens: [nm, B_mb, 1] (replicated over
            pod); staged leaves [1, bps, B, ...] (pod-sharded stage dim);
            stage_mask [1, bps] marks real (non-padding) block slots;
            starts: [nm, B_mb] per-slot first valid positions (replicated,
            ignored unless per_slot_start). In paged mode staged leaves are
            [1, bps, N, KVH, Pg, D] pools and ``starts`` is the pair
            ``(block_tables [nm, B_mb, MP], seq_lens [nm, B_mb])``."""
            s_idx = jax.lax.axis_index("pod")
            my_params = jax.tree.map(lambda x: x[0], params[seg.name])
            my_cache = jax.tree.map(lambda x: x[0], staged_cache)
            my_mask = stage_mask[0]
            B_mb = tokens.shape[1]
            d = cfg.d_model
            V = cfg.vocab_size

            def embed(tok):
                e = jnp.take(params["embed"], tok, axis=0)
                return e.astype(L.DEFAULT_DTYPE)

            def head(h):
                hn = L.rmsnorm(h[:, -1], params["ln_f"], cfg.norm_eps)
                w = (params["embed"].T if cfg.tie_embeddings
                     else params["head"])
                return jnp.einsum("bd,dv->bv", hn, w,
                                  preferred_element_type=jnp.float32)

            # sealed boundary payload carried between ticks
            zero_h = jnp.zeros((B_mb, 1, d), L.DEFAULT_DTYPE)
            if seal_on:
                c0, sc0 = sealing.seal_array(zero_h, jnp.uint32(0), 0,
                                             use_kernel=use_kernel)
                recv0 = (c0, sc0)
            else:
                recv0 = zero_h

            outputs0 = jnp.zeros((nm, B_mb, V), jnp.float32)

            def tick(carry, t):
                recv, cache_st, outputs = carry
                m_my = t - s_idx
                valid = (m_my >= 0) & (m_my < nm)
                m_idx = jnp.clip(m_my, 0, nm - 1)

                # stage input: stage 0 embeds its microbatch, others unseal
                tok = jax.lax.dynamic_index_in_dim(tokens, m_idx, 0,
                                                   keepdims=False)
                x0 = embed(tok)
                if seal_on:
                    step_ctr = jnp.uint32(t)
                    h_recv = sealing.unseal_array(
                        recv[0], recv[1], (B_mb, 1, d), key, step_ctr,
                        dtype=L.DEFAULT_DTYPE, use_kernel=use_kernel)
                else:
                    h_recv = recv
                x_in = jnp.where(s_idx == 0, x0, h_recv)

                if paged:
                    # pages are shared across rows — run the stage over the
                    # whole pool with this microbatch's table rows; commit
                    # only on valid ticks (warm-up/drain inputs are garbage)
                    bt_mb = jax.lax.dynamic_index_in_dim(starts[0], m_idx, 0,
                                                         keepdims=False)
                    sl_mb = jax.lax.dynamic_index_in_dim(starts[1], m_idx, 0,
                                                         keepdims=False)
                    h, new_pool = stage_run(my_params, cache_st, my_mask,
                                            x_in, None, paged=(bt_mb, sl_mb))
                    cache_st = jax.tree.map(
                        lambda new, old: jnp.where(valid, new, old),
                        new_pool, cache_st)
                else:
                    # my stage's cache slice for this microbatch
                    cache_sl = _batch_slice(cache_st, m_idx * B_mb, B_mb)
                    st = None
                    if per_slot_start:
                        st = jax.lax.dynamic_index_in_dim(starts, m_idx, 0,
                                                          keepdims=False)
                    h, new_sl = stage_run(my_params, cache_sl, my_mask, x_in,
                                          cache_len, start=st)
                    # only commit the slice when this tick is valid for me
                    new_sl = jax.tree.map(
                        lambda new, old: jnp.where(valid, new, old),
                        new_sl, cache_sl)
                    cache_st = _batch_update(cache_st, new_sl, m_idx * B_mb)

                # seal + rotate boundary activation to the next stage
                if seal_on:
                    payload = sealing.seal_array(h, key, jnp.uint32(t + 1),
                                                 use_kernel=use_kernel)
                else:
                    payload = h
                perm = [(i, (i + 1) % S) for i in range(S)]
                recv_next = jax.tree.map(
                    lambda x: jax.lax.ppermute(x, "pod", perm), payload)

                # last stage emits logits for microbatch t - (S-1)
                lg = head(h)
                m_out = jnp.clip(t - (S - 1), 0, nm - 1)
                write = (s_idx == S - 1) & (t >= S - 1)
                upd = jax.lax.dynamic_update_slice_in_dim(
                    outputs, lg[None], m_out, axis=0)
                outputs = jnp.where(write, upd, outputs)
                return (recv_next, cache_st, outputs), None

            (_, cache_fin, outputs), _ = jax.lax.scan(
                tick, (recv0, my_cache, outputs0), jnp.arange(nm + S - 1))
            cache_out = jax.tree.map(lambda x: x[None], cache_fin)
            return outputs, cache_out

        # ---- shard_map wrapper ------------------------------------------
        def step_fn(params, cache, batch, key):
            tokens = batch["tokens"]                   # [B, 1]
            B = tokens.shape[0]
            B_mb = B // nm
            tok_stream = tokens.reshape(nm, B_mb, 1)
            # uneven stages make staging a real gather (not a free reshape);
            # serving loops should stage params/cache once outside the loop
            # (stage_params/stage_cache + prestaged_*=True) rather than
            # re-gather per token — the cache round-trips twice otherwise
            staged_params = params if prestaged_params \
                else self.stage_params(params)
            start_vec = None
            bt_vec = sl_vec = None
            if paged:
                staged_cache, bt_vec, sl_vec = cache
                cache_len = jnp.int32(0)                    # unused
                starts = (bt_vec.reshape(nm, B_mb, -1),
                          sl_vec.reshape(nm, B_mb))
                starts_spec = (P(), P())
            elif per_slot_start:
                staged_cache, cache_len, start_vec = cache
                starts = start_vec.reshape(nm, B_mb)
                starts_spec = P()
            else:
                if prestaged_cache:
                    staged_cache, cache_len = cache
                else:
                    staged_cache, cache_len = self.stage_cache(cache)
                starts = jnp.zeros((nm, B_mb), jnp.int32)   # unused
                starts_spec = P()
            stage_mask = jnp.asarray(self._mask)

            param_specs = self._param_specs_tree(staged_params)
            cache_specs = jax.tree.map(
                lambda x: P("pod", *([None] * (x.ndim - 1))), staged_cache)
            body = functools.partial(pipeline_body)

            with R.axis_rules(mesh, R.PIPE_RULES):
                outputs, new_cache = jax.shard_map(
                    body, mesh=mesh,
                    in_specs=(param_specs, cache_specs, P("pod", None),
                              P(), starts_spec, P(), P()),
                    out_specs=(P("pod"), cache_specs),
                    axis_names={"pod"}, check_vma=False,
                )(staged_params, staged_cache, stage_mask, tok_stream,
                  starts, cache_len, key)
            # stages stack outputs along dim 0; the last nm rows are real
            logits = outputs[-nm:].reshape(B, -1)
            if paged:
                cache_out = (new_cache, bt_vec,
                             jnp.where(sl_vec > 0, sl_vec + 1, 0))
            elif per_slot_start:
                cache_out = (new_cache, cache_len + 1, start_vec)
            elif prestaged_cache:
                cache_out = (new_cache, cache_len + 1)
            else:
                cache_out = self.unstage_cache(new_cache, cache_len + 1)
            return logits, cache_out

        return step_fn
