from .pipeline import PipelinedDecoder, pipeline_applicable
from .steps import (make_train_step, make_prefill_step, make_decode_step,
                    param_shardings, opt_shardings, batch_shardings,
                    cache_shardings, abstract_inputs)
from .train_loop import TrainLoop, TrainLoopConfig
from .ft import HeartbeatMonitor, OnlineReplanner
