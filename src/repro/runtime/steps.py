"""Step builders: jit-compiled train / prefill / decode steps with logical
sharding, plus the multi-pod training variant with int8 error-feedback
gradient exchange across pods (optim/compression.py).

These are what both the launchers and the dry-run lower: the dry-run calls
``.lower(...).compile()`` on exactly these functions.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ShapeConfig
from repro.models.api import ModelAPI
from repro.models import layers as L
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig, AdamWState
from repro.optim.compression import compressed_psum_pod, init_error_feedback
from repro.sharding import rules as R


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------
def param_shardings(api: ModelAPI, mesh: Mesh, rules=None):
    rules = rules or R.PARAM_RULES
    specs = api.param_specs()
    return jax.tree.map(
        lambda s: R.logical_sharding(s.shape, s.axes, mesh, rules), specs,
        is_leaf=lambda x: isinstance(x, L.ParamSpec))


def opt_shardings(api: ModelAPI, mesh: Mesh, rules=None) -> AdamWState:
    ps = param_shardings(api, mesh, rules)
    return AdamWState(ps, ps, ps)


def batch_shardings(api: ModelAPI, shape: ShapeConfig, mesh: Mesh,
                    rules=None) -> Dict[str, Any]:
    rules = rules or R.ACT_RULES
    axes = api.input_axes(shape)
    specs = api.input_specs(shape)
    return {k: R.logical_sharding(specs[k].shape, axes[k], mesh, rules)
            for k in specs}


def cache_shardings(api: ModelAPI, batch: int, mesh: Mesh, rules=None,
                    max_seq: Optional[int] = None):
    rules = rules or R.ACT_RULES
    specs, axes = api.init_cache_specs(batch, max_seq)
    return jax.tree.map(
        lambda s, a: R.logical_sharding(s.shape, tuple(a), mesh, rules),
        specs, axes,
        is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "dtype"))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Train step (GSPMD; DP over pod+data, TP/EP over model, FSDP over data)
# ---------------------------------------------------------------------------
def make_train_step(api: ModelAPI, mesh: Mesh, opt_cfg: AdamWConfig,
                    shape: ShapeConfig, *, act_rules=None, param_rules=None,
                    compress_pod_grads: bool = False):
    act_rules = act_rules or R.ACT_RULES
    ps = param_shardings(api, mesh, param_rules)
    os_ = opt_shardings(api, mesh, param_rules)
    bs = batch_shardings(api, shape, mesh, act_rules)
    rep = replicated(mesh)

    if compress_pod_grads and "pod" in mesh.axis_names:
        return _make_train_step_compressed(api, mesh, opt_cfg, shape,
                                           ps, os_, bs, rep)

    def train_step(params, opt_state, batch, step):
        with R.axis_rules(mesh, act_rules):
            loss, grads = jax.value_and_grad(api.loss_fn)(params, batch)
        new_params, new_opt, gnorm = adamw.update(opt_cfg, grads, opt_state, step)
        return loss, new_params, new_opt, gnorm

    return jax.jit(
        train_step,
        in_shardings=(ps, os_, bs, rep),
        out_shardings=(rep, ps, os_, rep),
        donate_argnums=(0, 1),
    )


def _make_train_step_compressed(api, mesh, opt_cfg, shape, ps, os_, bs, rep):
    """Manual over pod: per-pod grads -> int8 EF exchange -> update.

    The error-feedback buffer rides in an extended opt state tuple.
    """
    num_pods = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]

    def body(params, opt_state, ef, batch, step):
        with R.axis_rules(mesh, R.PIPE_RULES):
            loss, grads = jax.value_and_grad(api.loss_fn)(params, batch)
        grads, ef = compressed_psum_pod(grads, ef, "pod", num_pods)
        loss = jax.lax.pmean(loss, "pod")
        new_params, new_opt, gnorm = adamw.update(opt_cfg, grads, opt_state, step)
        return loss, new_params, new_opt, ef, gnorm

    def specs_of(tree, batch_dim_pod=False):
        def one(x):
            if batch_dim_pod:
                return P("pod", *([None] * (max(x.ndim, 1) - 1)))
            return P(*([None] * getattr(x, "ndim", 0)))
        return jax.tree.map(one, tree)

    def train_step(params, opt_state, ef, batch, step):
        pspec = jax.tree.map(lambda s: P(*([None] * len(s.shape))),
                             api.param_specs(),
                             is_leaf=lambda x: isinstance(x, L.ParamSpec))
        ospec = AdamWState(pspec, pspec, pspec)
        bspec = {k: P("pod", *([None] * (v.ndim - 1))) for k, v in batch.items()}
        efspec = pspec
        fn = jax.shard_map(
            body, mesh=mesh,
            in_specs=(pspec, ospec, efspec, bspec, P()),
            out_specs=(P(), pspec, ospec, efspec, P()),
            axis_names={"pod"}, check_vma=False)
        return fn(params, opt_state, ef, batch, step)

    ef_shard = ps  # error feedback sharded like params (f32)
    return jax.jit(
        train_step,
        in_shardings=(ps, os_, ef_shard, bs, rep),
        out_shardings=(rep, ps, os_, ef_shard, rep),
        donate_argnums=(0, 1, 2),
    )


# ---------------------------------------------------------------------------
# Serve steps (GSPMD)
# ---------------------------------------------------------------------------
def _logits_sharding(api: ModelAPI, shape: ShapeConfig, mesh: Mesh, rules,
                     sharded_logits: bool):
    if not sharded_logits:
        return replicated(mesh)
    return R.logical_sharding((shape.global_batch, api.cfg.vocab_size),
                              ("act_batch", "act_vocab"), mesh, rules)


def make_prefill_step(api: ModelAPI, mesh: Mesh, shape: ShapeConfig, *,
                      act_rules=None, param_rules=None,
                      sharded_logits: bool = False):
    act_rules = act_rules or R.ACT_RULES
    ps = param_shardings(api, mesh, param_rules)
    bs = batch_shardings(api, shape, mesh, act_rules)
    cs = cache_shardings(api, shape.global_batch, mesh, act_rules,
                         max_seq=shape.seq_len)
    ls = _logits_sharding(api, shape, mesh, act_rules, sharded_logits)

    def prefill_step(params, batch):
        with R.axis_rules(mesh, act_rules):
            return api.prefill_fn(params, batch)

    return jax.jit(prefill_step, in_shardings=(ps, bs),
                   out_shardings=(ls, cs))


def make_decode_step(api: ModelAPI, mesh: Mesh, shape: ShapeConfig, *,
                     act_rules=None, param_rules=None,
                     sharded_logits: bool = False):
    act_rules = act_rules or R.ACT_RULES
    ps = param_shardings(api, mesh, param_rules)
    bs = batch_shardings(api, shape, mesh, act_rules)
    cs = cache_shardings(api, shape.global_batch, mesh, act_rules,
                         max_seq=shape.seq_len)
    ls = _logits_sharding(api, shape, mesh, act_rules, sharded_logits)

    def decode_step(params, cache, batch):
        with R.axis_rules(mesh, act_rules):
            return api.decode_fn(params, cache, batch)

    return jax.jit(decode_step, in_shardings=(ps, cs, bs),
                   out_shardings=(ls, cs), donate_argnums=(1,))


def abstract_inputs(api: ModelAPI, shape: ShapeConfig):
    """ShapeDtypeStructs for (params, [opt], batch, cache) used by dryrun."""
    params = api.abstract_params()
    batch = api.input_specs(shape)
    out = {"params": params, "batch": batch}
    if shape.kind == "train":
        f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
        out["opt"] = AdamWState(jax.tree.map(f32, params),
                                jax.tree.map(f32, params),
                                jax.tree.map(f32, params))
    if shape.kind == "decode":
        cache, _ = api.init_cache_specs(shape.global_batch, shape.seq_len)
        out["cache"] = cache
    return out
