"""Fault-tolerant training loop.

Features (the large-scale-runnability checklist):
* checkpoint/restart — atomic async checkpoints every N steps, exact resume
  (params, optimizer, data-iterator cursor, RNG-free determinism);
* preemption handling — SIGTERM/flag triggers a final blocking save;
* straggler detection — per-step wall-time EMA; a step slower than
  ``straggler_factor``x the EMA fires the on_straggler hook (at scale:
  re-plan placement via the Serdab solver / evict the domain);
* elastic restore — checkpoints re-shard onto whatever mesh the loop was
  constructed with (checkpoint/manager.py does device_put per leaf).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0
    ema_decay: float = 0.9


class TrainLoop:
    def __init__(self, *, train_step, params, opt_state, data,
                 ckpt: Optional[CheckpointManager] = None,
                 cfg: TrainLoopConfig = TrainLoopConfig(),
                 shardings: Optional[Any] = None,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None,
                 extra_step_args: tuple = ()):
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.data = data
        self.ckpt = ckpt
        self.cfg = cfg
        self.shardings = shardings
        self.on_straggler = on_straggler
        self.extra_step_args = extra_step_args
        self.step = 0
        self.losses: list = []
        self.straggler_events: list = []
        self._preempted = False
        self._ema: Optional[float] = None
        self._measured = 0                 # steps timed (step 0 = compile)

    # -- preemption -----------------------------------------------------
    def install_signal_handler(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)

    def preempt(self):
        """Programmatic preemption (tests / orchestrator)."""
        self._preempted = True

    # -- checkpoint -----------------------------------------------------
    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state}

    def save(self, block: bool = False):
        if self.ckpt is None:
            return
        extra = {"data": self.data.state_dict() if hasattr(self.data, "state_dict") else {},
                 "step": self.step}
        self.ckpt.save(self.step, self._state_tree(), extra=extra, block=block)

    def try_restore(self) -> bool:
        if self.ckpt is None:
            return False
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        like = self._state_tree()
        restored = self.ckpt.restore(latest, like, self.shardings)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        extra = self.ckpt.manifest(latest)["extra"]
        if hasattr(self.data, "load_state_dict") and extra.get("data"):
            self.data.load_state_dict(extra["data"])
        self.step = int(extra.get("step", latest))
        return True

    # -- main loop --------------------------------------------------------
    def run(self, num_steps: Optional[int] = None) -> Dict:
        n = num_steps if num_steps is not None else self.cfg.total_steps
        end = self.step + n
        while self.step < end and not self._preempted:
            t0 = time.monotonic()      # include the input pipeline: a slow
            batch = next(self.data)    # host data feed is also a straggler

            out = self.train_step(self.params, self.opt_state,
                                  *self.extra_step_args, batch,
                                  np.int32(self.step))
            if len(out) == 4:
                loss, self.params, self.opt_state, gnorm = out
            else:  # compressed variant returns error-feedback too
                loss, self.params, self.opt_state, ef, gnorm = out
                self.extra_step_args = (ef,)
            loss = float(loss)
            dt = time.monotonic() - t0
            # straggler detection on steady-state steps; the first measured
            # step is compile-dominated and never seeds the EMA
            self._measured += 1
            if self._measured >= 2:
                if self._ema is None:
                    self._ema = dt
                elif dt > self.cfg.straggler_factor * self._ema:
                    self.straggler_events.append((self.step, dt, self._ema))
                    if self.on_straggler:
                        self.on_straggler(self.step, dt, self._ema)
                    # do not fold the outlier into the EMA
                else:
                    self._ema = (self.cfg.ema_decay * self._ema
                                 + (1 - self.cfg.ema_decay) * dt)
            self.losses.append(loss)
            self.step += 1
            if self.ckpt and self.step % self.cfg.ckpt_every == 0:
                self.save()
        if self._preempted:
            self.save(block=True)     # final blocking save on preemption
        if self.ckpt:
            self.ckpt.wait()
        return {"losses": self.losses, "step": self.step,
                "stragglers": self.straggler_events,
                "preempted": self._preempted}
