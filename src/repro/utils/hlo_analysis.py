"""Roofline terms from a compiled dry-run artifact.

cost_analysis() provides HLO FLOPs and bytes; collective bytes are NOT in
cost_analysis, so we parse the (post-SPMD-partitioning) HLO text and sum the
operand sizes of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute op.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of 'bf16[128,256]{...}' -> 2*128*256. Tuples handled upstream."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")


def _parse_computations(hlo_text: str):
    """Split HLO text into {comp_name: [lines]}; returns (comps, entry).

    A computation header is ``name (params...) -> type {`` — params may
    contain nested parens (tuple types), so detect headers as lines ending
    in ``{`` with ``->`` and no ``=`` before the arrow (instructions always
    have ``name = ...``)."""
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        s = line.strip()
        is_header = (s.endswith("{") and "->" in s
                     and "=" not in s.split("->", 1)[0])
        m = _COMP_HEAD_RE.match(s) if is_header else None
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
        elif cur is not None:
            comps[cur].append(s)
    return comps, entry


def _line_collective(line: str):
    """(op_kind, bytes) for a collective instruction line, else None."""
    for op in COLLECTIVE_OPS:
        if re.search(rf"= [^=]*\b{op}(-start)?\(", line):
            lhs = line.split("=", 1)[1]
            head = lhs.split(op, 1)[0]
            b = _shape_bytes(head)
            if f"{op}-start(" in line:
                b //= 2                # start op output is (inflight, result)
            return op, b
    return None


def _trip_count(cond_lines) -> int:
    """Scan-condition computations compare the induction var to a constant."""
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def walk_collectives(hlo_text: str) -> Dict[str, int]:
    """Collective bytes with while-loops multiplied by their trip counts.

    Builds the computation call graph (while/fusion/call/conditional edges),
    memoizes per-computation collective bytes, and accumulates from ENTRY.
    """
    comps, entry = _parse_computations(hlo_text)
    memo: Dict[str, Dict[str, float]] = {}

    def cost(name: str, stack=()) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return {k: 0.0 for k in COLLECTIVE_OPS}
        total = {k: 0.0 for k in COLLECTIVE_OPS}
        for line in comps[name]:
            hit = _line_collective(line)
            if hit:
                total[hit[0]] += hit[1]
            # call edges
            if " while(" in line:
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                if mb:
                    trips = _trip_count(comps.get(mc.group(1), [])) if mc else 1
                    sub = cost(mb.group(1), stack + (name,))
                    for k in COLLECTIVE_OPS:
                        total[k] += trips * sub[k]
            else:
                for ref in re.finditer(
                        r"(?:to_apply|calls)=%?([\w\.\-]+)", line):
                    sub = cost(ref.group(1), stack + (name,))
                    for k in COLLECTIVE_OPS:
                        total[k] += sub[k]
                mb = re.search(r"branch_computations=\{([^}]*)\}", line)
                if mb:
                    for branch in mb.group(1).split(","):
                        sub = cost(branch.strip().lstrip("%"), stack + (name,))
                        for k in COLLECTIVE_OPS:
                            total[k] += sub[k]
        memo[name] = total
        return total

    if entry is None:
        entry = next(iter(comps)) if comps else None
    result = cost(entry) if entry else {k: 0.0 for k in COLLECTIVE_OPS}
    return {k: int(v) for k, v in result.items()}


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Back-compat flat count (no trip multiplication) plus the walked one."""
    out = walk_collectives(hlo_text)
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        hit = _line_collective(line.strip())
        if hit:
            counts[hit[0]] += 1
    out["_counts"] = counts
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    chips: int
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * self.peak_flops)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * self.hbm_bw)

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / (self.chips * self.ici_bw)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
        }


def cost_summary(compiled) -> Dict[str, float]:
    """Extract flops + bytes-accessed from compiled.cost_analysis()."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):           # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = 0.0
    for k, v in ca.items():
        if k.startswith("bytes accessed") and "{" in k:
            # per-operand entries; 'bytes accessed' alone is the total
            continue
        if k == "bytes accessed":
            byts = float(v)
    if byts == 0.0:
        byts = sum(float(v) for k, v in ca.items()
                   if k.startswith("bytes accessed"))
    return {"flops": flops, "bytes": byts}
