"""Analytic FLOP / HBM-byte models per (arch x shape).

XLA's ``HloCostAnalysis`` counts each ``while`` body ONCE (scan bodies are
not multiplied by trip count), so compiled cost_analysis massively
under-reports for scan-over-layers programs. The roofline therefore uses
these documented analytic models for compute/memory terms; collective bytes
come from the HLO call-graph walk (hlo_analysis.walk_collectives) which
*does* multiply by trip counts. EXPERIMENTS.md §Roofline records the
convention.

Formulas (bf16 compute, f32 optimizer):
  matmul flops        = 2 * tokens * active_params(block)
  attention flops     = 4 * B * H * hd * S * ctx_eff   (qk + pv, causal 1/2)
  train multiplier    = 4x fwd for scanned blocks (fwd + remat-refwd + 2 bwd),
                        3x for embed/head (no remat)
  train HBM/param     = 36 B  (3 param reads bf16, grad r/w bf16,
                        master+m+v read/write f32, param write bf16)
  activation traffic  = 2 * L * B * S * d * 2B  (block-boundary saves + reads)
  decode HBM          = active params (2B) + full KV cache read + write slice
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig, ShapeConfig, MOE, SSM, HYBRID, ENCDEC, VLM

BF16 = 2
F32 = 4


def _attn_ctx(cfg: ArchConfig, S: int) -> float:
    """Effective context per query for training/prefill (causal avg S/2,
    sliding window caps it)."""
    if cfg.sliding_window:
        return min(cfg.sliding_window, S / 2)
    if cfg.family == SSM:
        return 0.0                     # recurrent, no quadratic term
    return S / 2


def _block_attn_flops(cfg: ArchConfig, B: int, S: int, ctx: float) -> float:
    return 4.0 * B * cfg.num_heads * cfg.head_dim * S * ctx


def _ssm_extra_flops(cfg: ArchConfig, tokens: int) -> float:
    """mLSTM outer products / selective-scan state updates."""
    if cfg.family == SSM:
        return 6.0 * tokens * cfg.num_heads * cfg.head_dim ** 2
    if cfg.family == HYBRID:
        return 6.0 * tokens * cfg.d_model * cfg.ssm_state
    return 0.0


@dataclasses.dataclass
class CostEstimate:
    flops: float
    hbm_bytes: float
    model_flops: float                 # 6*N*D train / 2*N*D inference

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.flops, 1.0)


def estimate(cfg: ArchConfig, shape: ShapeConfig, *, cache_bytes: int = 2,
             state_bytes: int = 4) -> CostEstimate:
    B, S = shape.global_batch, shape.seq_len
    N_active = cfg.total_active_params()
    N_total = cfg.total_params()
    embed_params = cfg.embed_params()
    body_active = N_active - embed_params
    d = cfg.d_model

    if shape.kind == "train":
        tokens = B * S
        fwd_blocks = 2.0 * tokens * body_active + cfg.num_layers * \
            _block_attn_flops(cfg, B, S, _attn_ctx(cfg, S)) + \
            _ssm_extra_flops(cfg, tokens)
        if cfg.family == ENCDEC:
            enc_tokens = B * cfg.encoder_seq
            fwd_blocks += cfg.encoder_layers * _block_attn_flops(
                cfg, B, cfg.encoder_seq, cfg.encoder_seq / 2)
        fwd_embed = 2.0 * tokens * embed_params / (2 if cfg.tie_embeddings else 1)
        flops = 4.0 * fwd_blocks + 3.0 * fwd_embed * (2 if cfg.tie_embeddings else 1)
        hbm = N_total * 36.0 + 2.0 * cfg.num_layers * tokens * d * BF16 \
            + 2.0 * tokens * d * BF16
        model_flops = 6.0 * N_active * tokens
        return CostEstimate(flops, hbm, model_flops)

    if shape.kind == "prefill":
        tokens = B * S
        flops = 2.0 * tokens * N_active + cfg.num_layers * \
            _block_attn_flops(cfg, B, S, _attn_ctx(cfg, S)) + \
            _ssm_extra_flops(cfg, tokens)
        cb = _kv_cache_bytes(cfg, B, S, cache_bytes, state_bytes)
        hbm = N_total * BF16 + 2.0 * cfg.num_layers * tokens * d * BF16 \
            + cb
        return CostEstimate(flops, hbm, 2.0 * N_active * tokens)

    # decode: one token per sequence against a seq_len cache
    tokens = B
    ctx = min(cfg.sliding_window, S) if cfg.sliding_window else S
    if cfg.family == SSM:
        attn = _ssm_extra_flops(cfg, tokens) * cfg.num_layers / 2
    else:
        attn = cfg.num_layers * 4.0 * B * cfg.num_heads * cfg.head_dim * ctx
        attn += _ssm_extra_flops(cfg, tokens)
    flops = 2.0 * tokens * N_active + attn
    cb = _kv_cache_bytes(cfg, B, S, cache_bytes, state_bytes)
    hbm = N_total * BF16 + cb  # read params + read cache (+eps write)
    return CostEstimate(flops, hbm, 2.0 * N_active * tokens)


def _kv_cache_bytes(cfg: ArchConfig, B: int, S: int, cache_bytes: int = 2,
                    state_bytes: int = 4) -> float:
    if cfg.family == SSM:
        pairs = cfg.num_layers // 2
        m = B * cfg.num_heads * cfg.head_dim * (cfg.head_dim + 2) * state_bytes
        s = 4 * B * cfg.num_heads * cfg.head_dim * state_bytes
        return pairs * (m + s)
    ctx = min(cfg.sliding_window, S) if cfg.sliding_window else S
    kv = 2.0 * cfg.num_layers * B * cfg.num_kv_heads * ctx * cfg.head_dim * cache_bytes
    if cfg.family == HYBRID:
        kv += cfg.num_layers * B * cfg.d_model * (cfg.ssm_state * F32 +
                                                  (cfg.conv_kernel - 1) * BF16)
    if cfg.family == ENCDEC:
        kv += 2.0 * cfg.num_layers * B * cfg.num_kv_heads * cfg.encoder_seq \
            * cfg.head_dim * BF16
    return kv
