"""Sharded checkpointing with atomic commit, async save, retention, and
elastic restore (re-sharding onto a different mesh).

Layout:  <dir>/step_<N>/  arrays.npz + manifest.json  (+ .sha256)
         <dir>/LATEST     -> committed step number (written last = atomic)

Restore never requires the saving mesh: leaves are materialized host-side
and ``jax.device_put`` re-shards them onto the target shardings — this is
what elastic scaling uses when the pod count changes between runs.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _np_dtype(dt):
    try:
        return np.dtype(dt)
    except TypeError:
        return np.float32                    # extended dtypes restored via jnp


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        named[name] = leaf
    return named, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             block: bool = False) -> None:
        self.wait()
        named, _ = _flatten_with_names(tree)
        # numpy cannot serialize bfloat16 — widen to f32 (lossless), the
        # restore path casts back to the target leaf dtype.
        def to_host(v):
            a = np.asarray(v)
            if a.dtype.kind == "V":          # ml_dtypes (bf16 etc.)
                return np.asarray(jax.numpy.asarray(v).astype(jax.numpy.float32))
            return a
        host = {k: to_host(v) for k, v in named.items()}

        def commit():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            manifest = {
                "step": step,
                "time": time.time(),
                "extra": extra or {},
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in host.items()},
            }
            blob = json.dumps(manifest, indent=1).encode()
            with open(os.path.join(tmp, "manifest.json"), "wb") as f:
                f.write(blob)
            with open(os.path.join(tmp, "manifest.sha256"), "w") as f:
                f.write(hashlib.sha256(blob).hexdigest())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                      # atomic commit
            with open(os.path.join(self.dir, "LATEST"), "w") as f:
                f.write(str(step))
            self._gc()

        if self.async_save and not block:
            self._pending = threading.Thread(target=commit, daemon=True)
            self._pending.start()
        else:
            commit()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            s = int(f.read().strip())
        return s if s in self.all_steps() else (self.all_steps() or [None])[-1]

    def restore(self, step: int, like: Any, shardings: Any = None,
                verify: bool = True) -> Any:
        """``like``: pytree (arrays or ShapeDtypeStructs) giving structure.
        ``shardings``: matching tree of NamedShardings for elastic re-shard."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json"), "rb") as f:
            blob = f.read()
        if verify:
            with open(os.path.join(d, "manifest.sha256")) as f:
                assert hashlib.sha256(blob).hexdigest() == f.read().strip(), \
                    "checkpoint manifest corrupted"
        data = np.load(os.path.join(d, "arrays.npz"))
        named, treedef = _flatten_with_names(like)
        leaves = []
        shard_named = None
        if shardings is not None:
            shard_named, _ = _flatten_with_names(shardings)
        for name, leaf in named.items():
            arr = data[name]
            want = tuple(leaf.shape)
            if tuple(arr.shape) != want:
                raise ValueError(f"{name}: checkpoint shape {arr.shape} != {want}")
            arr = arr.astype(_np_dtype(leaf.dtype)) \
                if str(arr.dtype) != str(leaf.dtype) else arr
            if shard_named is not None:
                leaves.append(jax.device_put(
                    jax.numpy.asarray(arr).astype(leaf.dtype),
                    shard_named[name]))
            else:
                leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
        return jax.tree.unflatten(treedef, leaves)

    def manifest(self, step: int) -> Dict:
        with open(os.path.join(self.dir, f"step_{step}", "manifest.json")) as f:
            return json.load(f)
