from .manager import CheckpointManager
