"""AdamW with bf16 params + f32 master/moments, cosine schedule, global-norm
clipping. Hand-rolled (no optax in this container) but API-compatible in
spirit: ``init(params) -> state``, ``update(grads, state, params, step)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    master: Any          # f32 copy of params
    m: Any
    v: Any


def init(params) -> AdamWState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(jax.tree.map(f32, params),
                      jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params))


def schedule(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def update(cfg: AdamWConfig, grads, state: AdamWState, step):
    """Returns (new_params_bf16, new_state)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = jnp.asarray(step, jnp.float32) + 1.0
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(g, master, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        p_new = master - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                               + cfg.weight_decay * master)
        return p_new, m_new, v_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_ma = jax.tree.leaves(state.master)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(g, ma, m, v) for g, ma, m, v
           in zip(flat_g, flat_ma, flat_m, flat_v)]
    new_master = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(lambda ma, p: ma.astype(p.dtype),
                              new_master, grads)
    return new_params, AdamWState(new_master, new_m, new_v), gnorm
