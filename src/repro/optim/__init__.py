from .adamw import AdamWConfig, AdamWState, init, update, schedule, global_norm
from .compression import compressed_psum_pod, init_error_feedback
