"""Cross-pod gradient compression with error feedback.

Inside the multi-pod train step the ``pod`` mesh axis is *manual*
(shard_map): each pod computes gradients over its own batch shard, then
exchanges int8-quantized gradients over the DCN (1 byte/element on the wire
instead of 4) and folds the quantization error into an error-feedback buffer
that is added back before the next step — the standard EF-SGD trick, so the
compression is unbiased over time.

Two pods exchange via a single ppermute (the production mesh); >2 pods fall
back to f32 psum (ring-int8 is a TODO recorded in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_pod(grads: Any, ef: Any, axis: str = "pod",
                        num_pods: int = 2):
    """grads, ef: pytrees (f32/bf16). Returns (reduced grads, new ef).

    Must run inside a shard_map with ``axis`` manual.
    """
    if num_pods != 2:
        reduced = jax.tree.map(
            lambda g: jax.lax.psum(g.astype(jnp.float32), axis) / num_pods, grads)
        return reduced, ef

    perm = [(0, 1), (1, 0)]

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quantize(gf)
        # exchange int8 payload + its scale with the peer pod
        q_peer = jax.lax.ppermute(q, axis, perm)
        scale_peer = jax.lax.ppermute(scale, axis, perm)
        mine = q.astype(jnp.float32) * scale
        theirs = q_peer.astype(jnp.float32) * scale_peer
        new_e = gf - mine                      # local quantization residual
        return (mine + theirs) * 0.5, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    reduced = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_ef = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return reduced, new_ef


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
