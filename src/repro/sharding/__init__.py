from .rules import (PARAM_RULES, ACT_RULES, PIPE_RULES, SP_ACT_RULES, merge_rules,
                    resolve_spec, logical_sharding, axis_rules, constrain,
                    current_mesh)
