"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Arrays are annotated with *logical* axis names; a rule table maps each
logical name to an ordered list of mesh-axis candidates. ``resolve_spec``
walks the candidates and picks the first assignment that (a) divides the
dimension size and (b) does not reuse a mesh axis already claimed by another
dimension of the same array. This keeps every (arch x shape x mesh) cell
compilable even when e.g. ``num_kv_heads < model-axis size``.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# Candidate lists: tried in order; () = replicate.
Rules = Dict[str, List[Tuple[str, ...]]]

# ---------------------------------------------------------------------------
# Default rule tables
# ---------------------------------------------------------------------------
# Parameters. "embed"-type dims take FSDP ("data") sharding; head/ffn/expert
# dims take tensor parallelism ("model"); vocab is tensor-sharded.
PARAM_RULES: Rules = {
    "vocab":    [("model",), ()],
    "embed":    [("data",), ()],          # ZeRO-3 / FSDP axis
    "heads":    [("model",), ()],
    "kv_heads": [("model",), ()],
    "qkv":      [("model",), ()],
    "mlp":      [("model",), ()],
    "experts":  [("model",), ()],          # expert parallelism
    "layers":   [()],                       # scan dim: never shard
    "stages":   [("pod",), ()],             # pipeline stage dim
    "conv":     [()],
    "state":    [()],
    "head_dim": [()],
    None:       [()],
}

# Activations (train / prefill).
ACT_RULES: Rules = {
    "act_batch":   [("pod", "data"), ("data",), ()],
    "act_seq":     [()],                     # SP opt-in via perf rules
    "act_embed":   [()],
    "act_heads":   [("model",), ()],
    "act_kv_heads": [("model",), ()],
    "act_mlp":     [("model",), ()],
    "act_vocab":   [("model",), ()],
    "act_experts": [("model",), ()],
    "act_kv_seq":  [("model",), ()],         # distributed flash-decode
    "act_kv_batch": [("pod", "data"), ("data",), ()],
    "act_state":   [()],
    "layers":      [()],
    None:          [()],
}


# Sequence-parallel training rules: the residual stream (block boundaries,
# the tensors the remat scan SAVES) shards its sequence dim over ``model`` —
# Megatron-SP. Cuts saved-activation HBM by the TP degree; XLA inserts the
# all-gather before attention / reduce-scatter after, overlapping with
# compute. Opt-in: the paper-faithful baseline keeps activations unsharded.
SP_ACT_RULES: Rules = dict(ACT_RULES)
SP_ACT_RULES["act_seq_sp"] = [("model",), ()]
ACT_RULES = dict(ACT_RULES)
ACT_RULES["act_seq_sp"] = [()]
PIPE_RULES_SP_PLACEHOLDER = None  # (PIPE_RULES defined below)


# Rules for the body of the pipelined serve: the ``pod`` axis is manual
# (pipeline stages), so activation/cache rules may only use data/model.
PIPE_RULES: Rules = {
    "act_batch":   [("data",), ()],
    "act_seq":     [()],
    "act_embed":   [()],
    "act_heads":   [("model",), ()],
    "act_kv_heads": [("model",), ()],
    "act_mlp":     [("model",), ()],
    "act_vocab":   [("model",), ()],
    "act_experts": [("model",), ()],
    "act_kv_seq":  [("model",), ()],
    "act_kv_batch": [("data",), ()],
    "act_state":   [()],
    "act_seq_sp":  [()],
    "layers":      [()],
    None:          [()],
}


def merge_rules(base: Rules, override: Rules) -> Rules:
    out = dict(base)
    out.update(override)
    return out


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------
def resolve_spec(shape: Sequence[int], axes: Sequence[Optional[str]],
                 mesh: Mesh, rules: Rules) -> PartitionSpec:
    """Map logical axes -> PartitionSpec honoring divisibility & axis reuse."""
    if len(shape) != len(axes):
        raise ValueError(f"rank mismatch: shape {tuple(shape)} vs axes {tuple(axes)}")
    used: set = set()
    entries = []
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, name in zip(shape, axes):
        cands = rules.get(name, rules.get(None, [()]))
        chosen: Tuple[str, ...] = ()
        for cand in cands:
            cand = tuple(a for a in cand if a in axis_sizes)
            if not cand:
                chosen = ()
                break
            prod = 1
            for a in cand:
                prod *= axis_sizes[a]
            if any(a in used for a in cand):
                continue
            if dim % prod != 0:
                continue
            chosen = cand
            break
        used.update(chosen)
        entries.append(chosen if len(chosen) != 1 else chosen[0])
    # trim trailing replicated entries for tidiness
    while entries and entries[-1] == ():
        entries.pop()
    return PartitionSpec(*[e if e != () else None for e in entries])


def logical_sharding(shape: Sequence[int], axes: Sequence[Optional[str]],
                     mesh: Mesh, rules: Rules) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(shape, axes, mesh, rules))


# ---------------------------------------------------------------------------
# Thread-local rule context so model code can annotate without plumbing.
# ---------------------------------------------------------------------------
class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Rules] = None


_CTX = _Ctx()


class axis_rules:
    """Context manager enabling ``constrain`` inside model code."""

    def __init__(self, mesh: Mesh, rules: Optional[Rules] = None):
        self.mesh = mesh
        self.rules = rules if rules is not None else ACT_RULES

    def __enter__(self):
        self._prev = (_CTX.mesh, _CTX.rules)
        _CTX.mesh, _CTX.rules = self.mesh, self.rules
        return self

    def __exit__(self, *exc):
        _CTX.mesh, _CTX.rules = self._prev
        return False


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """``with_sharding_constraint`` under the active rule context (no-op
    outside one, so the same model code runs in single-device tests)."""
    mesh, rules = _CTX.mesh, _CTX.rules
    if mesh is None or mesh.size == 1:
        return x
    spec = resolve_spec(x.shape, axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
