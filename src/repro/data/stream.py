"""Video-frame chunk stream — the paper's IoT data model (Sec. IV).

Frames arrive as an unbounded stream aggregated into chunks of duration T
(size n). The synthetic source generates structured frames (moving blobs on
a textured background) so the privacy benchmarks have object-like content;
state is checkpointable like the token stream.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List

import numpy as np


@dataclasses.dataclass
class VideoChunkStream:
    resolution: int = 224
    chunk_size: int = 32               # n frames per chunk
    seed: int = 0
    chunk_index: int = 0

    def state_dict(self):
        return {"chunk_index": self.chunk_index, "seed": self.seed}

    def load_state_dict(self, s):
        self.chunk_index = int(s["chunk_index"])
        self.seed = int(s["seed"])

    def frame(self, chunk: int, i: int) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, chunk, i]))
        R = self.resolution
        yy, xx = np.mgrid[0:R, 0:R].astype(np.float32) / R
        # textured background + a moving bright "object" blob
        bg = 0.35 + 0.12 * np.sin(14 * xx + rng.uniform(0, 6)) * \
            np.cos(11 * yy + rng.uniform(0, 6))
        cx, cy = rng.uniform(0.25, 0.75, 2)
        r = rng.uniform(0.08, 0.18)
        blob = np.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / (2 * r * r)))
        img = np.clip(bg + 0.6 * blob + 0.02 * rng.standard_normal((R, R)), 0, 1)
        return np.stack([img, img * 0.9, img * 0.8], axis=-1).astype(np.float32)

    def __next__(self) -> List[np.ndarray]:
        c = self.chunk_index
        self.chunk_index += 1
        return [self.frame(c, i) for i in range(self.chunk_size)]

    def __iter__(self) -> Iterator[List[np.ndarray]]:
        return self
