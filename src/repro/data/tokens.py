"""Synthetic LM data pipeline with checkpointable iterator state.

Deterministic: batch at step s is a pure function of (seed, s), so resuming
from a checkpointed step reproduces the exact data order — the property the
fault-tolerance tests assert. A Zipf-ish marginal over the vocab plus a
shift-structure (labels = tokens rolled by 1 with noise) gives the model
something learnable for the end-to-end "loss goes down" example.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticTokenStream:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    step: int = 0                      # checkpointable cursor
    structure: float = 0.9             # P(next token follows the pattern)

    def state_dict(self) -> Dict:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, state: Dict) -> None:
        self.step = int(state["step"])
        self.seed = int(state["seed"])

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        # Zipf-ish marginal, then a deterministic successor pattern
        base = rng.zipf(1.3, size=(B, S)).astype(np.int64) % V
        succ = (base * 31 + 7) % V
        follow = rng.random((B, S)) < self.structure
        tokens = base.astype(np.int32)
        labels = np.where(follow, succ, rng.integers(0, V, (B, S))).astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.step)
        self.step += 1
        return b


@dataclasses.dataclass
class HostShardedStream:
    """Wraps a stream, yielding this host's shard — the multi-host data
    loading pattern (each host feeds its addressable devices)."""

    base: SyntheticTokenStream
    host_index: int = 0
    host_count: int = 1

    def __next__(self):
        b = next(self.base)
        B = b["tokens"].shape[0]
        per = B // self.host_count
        lo = self.host_index * per
        return {k: v[lo:lo + per] for k, v in b.items()}

    def __iter__(self):
        return self

    def state_dict(self):
        return self.base.state_dict()

    def load_state_dict(self, s):
        self.base.load_state_dict(s)
