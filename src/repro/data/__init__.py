from .tokens import SyntheticTokenStream, HostShardedStream
from .stream import VideoChunkStream
